"""Quickstart: the paper's cross-layer stack in 60 lines.

Build a hinted workflow (compiler layer), run it through the location-aware
store + proactive scheduler (storage + runtime layers), and compare against
the FCFS baseline — the paper's Figure-2 scenario, executable on a laptop.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (FCFSScheduler, HPC_CLUSTER, LocalityScheduler,
                        ProactiveScheduler, TaskGraph, WorkflowExecutor,
                        compile_workflow, simulate, size_hint, task)
from repro.core.workloads import fig2_workflow

# --- 1. the compiler layer: a hinted DAG (the paper's @ annotations) --------
g = TaskGraph()
g.add_data("raw", size_bytes=size_hint(64 * 1024 * 1024))        # @size
g.add_task("split", inputs=("raw",), outputs=("a", "b"),
           hints=task(compute="linear", io_ratio=0.5))           # @ratios
g.add_task("fft_a", inputs=("a",), outputs=("fa",),
           hints=task(compute="nlogn", io_ratio=1.0))            # @complexity
g.add_task("fft_b", inputs=("b",), outputs=("fb",),
           hints=task(compute="nlogn", io_ratio=1.0))
g.add_task("merge", inputs=("fa", "fb"), outputs=("out",),
           hints=task(compute="linear", io_ratio=0.5))

wf = compile_workflow(g)                     # sizes/costs/ranks propagate
print("critical path:", " -> ".join(wf.critical_path))
print("dataset sizes:", {k: f"{v/2**20:.0f}MiB" for k, v in wf.sizes.items()})

# --- 2. REAL execution with numpy bodies on the executor --------------------
bodies = {
    "split": lambda raw: {"a": raw[: len(raw) // 2], "b": raw[len(raw) // 2:]},
    "fft_a": lambda a: {"fa": np.fft.rfft(a).real.astype(np.float32)},
    "fft_b": lambda b: {"fb": np.fft.rfft(b).real.astype(np.float32)},
    "merge": lambda fa, fb: {"out": float(np.abs(fa).sum() + np.abs(fb).sum())},
}
for tid, fn in bodies.items():
    wf.graph.tasks[tid].fn = fn

ex = WorkflowExecutor(wf, ProactiveScheduler(wf), n_nodes=2,
                      inject_inputs={"raw": np.random.default_rng(0)
                                     .standard_normal(1 << 16)
                                     .astype(np.float32)})
res = ex.run()
print(f"\nexecuted: out={res.outputs['out']:.1f}  wall={res.wall_seconds:.3f}s"
      f"  locality hit rate={res.locality_hit_rate:.0%}")

# --- 3. the paper's comparison, at cluster scale in the simulator ----------
wf_big = compile_workflow(fig2_workflow(flops_per_byte=20_000), HPC_CLUSTER)
print("\n16-node simulation (paper's comparison):")
for name, factory in [("fcfs      ", FCFSScheduler),
                      ("locality  ", LocalityScheduler),
                      ("proactive ", ProactiveScheduler)]:
    r = simulate(wf_big, factory, n_nodes=16, hw=HPC_CLUSTER)
    print(f"  {name} makespan={r.makespan:7.1f}s  "
          f"moved={r.bytes_moved/2**30:5.2f}GiB  "
          f"hit={r.locality_hit_rate:5.1%}  io_wait={r.io_wait_total:6.1f}s")
