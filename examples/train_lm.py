"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production stack at laptop scale: synthetic corpus shards,
prefetching loader (the paper's pipelining), jitted train step with AdamW,
async checkpointing, and a mid-run simulated node failure with restart from
checkpoint — all the fault-tolerance machinery, observable in one run.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.configs.base import ModelConfig
from repro.train.loop import TrainConfig, train
from repro.train.optimizer import OptConfig

# ~100M params: a granite-family dense GQA decoder
CFG_100M = ModelConfig(
    name="granite-100m", family="dense",
    n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32768,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--fail-at", type=int, default=150,
                    help="simulate a node failure at this step (0 = off)")
    args = ap.parse_args()

    n_params = sum(x.size for x in __import__("jax").tree.leaves(
        __import__("jax").eval_shape(
            lambda: __import__("repro.models", fromlist=["init_params"])
            .init_params(CFG_100M, __import__("jax").random.PRNGKey(0)))))
    print(f"model: {CFG_100M.name}  params={n_params/1e6:.1f}M  "
          f"steps={args.steps}  tokens/step={args.batch * args.seq}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tc = TrainConfig(steps=args.steps, batch=args.batch, seq=args.seq,
                         ckpt_dir=ckpt_dir, ckpt_every=50,
                         simulate_failure_at=args.fail_at or None)
        oc = OptConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)

        def log(step, metrics):
            if step % 20 == 0:
                print(f"  step {step:4d}  loss={float(metrics['loss']):.4f}  "
                      f"lr={float(metrics['lr']):.2e}  "
                      f"gnorm={float(metrics['grad_norm']):.2f}", flush=True)

        r = train(CFG_100M, tc, oc, on_step=log)

    print(f"\nloss {r.losses[0]:.3f} -> {r.losses[-1]:.3f} over "
          f"{r.steps_done} steps ({r.wall_seconds:.0f}s, "
          f"{r.restarts} failure-restart(s), "
          f"{args.batch * args.seq * r.steps_done / r.wall_seconds:,.0f} tok/s)")
    assert r.losses[-1] < r.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
