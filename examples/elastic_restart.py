"""Elastic restart: checkpoint on one mesh, resume on another.

Simulates the 1000-node scenario at laptop scale: a run checkpoints, "loses
half its pod", and resumes from the same checkpoint on a reshaped mesh —
parameters are resharded by the divisibility-aware rules, and the
deterministic data pipeline replays the exact next batch.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import tempfile

import jax

from repro.configs import get_smoke
from repro.launch.mesh import make_local_mesh
from repro.models import init_params
from repro.train import checkpoint as ckpt
from repro.train.elastic import elastic_restore, shard_targets
from repro.train.optimizer import OptConfig, init_opt_state


def main() -> None:
    cfg = get_smoke("granite-3-2b")
    oc = OptConfig()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(oc, params)

    with tempfile.TemporaryDirectory() as d:
        print("training 'pod A' saves step 40 ...")
        ckpt.save({"p": params, "o": opt}, d, 40)

        # --- pod shrinks: new mesh shape -------------------------------------
        new_mesh = make_local_mesh(1, 1)   # stand-in for (8, 16) after losing hosts
        print(f"restarting on mesh {dict(new_mesh.shape)} ...")
        p2, o2, step = elastic_restore(cfg, oc, d, new_mesh)
        print(f"restored step {step}; resharded "
              f"{len(jax.tree.leaves(p2))} param leaves onto the new mesh")

        # verify bit-identical content
        ok = all(
            (jax.numpy.abs(a.astype(jax.numpy.float32)
                           - b.astype(jax.numpy.float32)).max() == 0)
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
        print("content identical after reshard:", bool(ok))

        # the targets the restore used (what a production launcher passes)
        tgt = shard_targets(cfg, oc, new_mesh)
        some = jax.tree.leaves(tgt["p"])[0]
        print("example target sharding:", some.sharding)


if __name__ == "__main__":
    main()
