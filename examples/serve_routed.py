"""Location-aware multi-engine serving (compute-on-data-path for inference).

Two engines ("nodes") serve sessions; a multi-turn conversation's follow-up
requests are routed BY THE LOCATION SERVICE to the engine already holding the
session's KV cache — vs. the baseline that picks engines at random and pays a
re-prefill on every miss.

    PYTHONPATH=src python examples/serve_routed.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke
from repro.core.locstore import LocStore
from repro.models import init_params
from repro.serve.engine import Router, ServingEngine


def main() -> None:
    cfg = dataclasses.replace(get_smoke("granite-3-2b"), dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    store = LocStore(2)
    engines = [ServingEngine(cfg, params, max_batch=4, max_seq=96, node=i,
                             store=store) for i in range(2)]
    router = Router(engines, store)
    rng = np.random.default_rng(0)

    # open 4 conversations
    sessions = []
    for i in range(4):
        eng = router.engine_for()
        sid = eng.submit(rng.integers(0, cfg.vocab, 8).tolist())
        sessions.append(sid)
        print(f"session {sid} opened on engine {eng.node} "
              f"(cache pinned via location service)")

    # 3 follow-up turns per session: the router finds the cache every time
    for turn in range(3):
        for sid in sessions:
            eng = router.engine_for(sid)
            eng.step()
            tokens = eng.sessions[sid].tokens
            print(f"  turn {turn}: session {sid} -> engine {eng.node} "
                  f"(hit) last_token={tokens[-1]}")

    print(f"\nlocation-service routing: {router.locality_hits} hits, "
          f"{router.locality_misses} misses")
    print(f"prefills run: {sum(e.prefills for e in engines)} "
          f"(= 4 initial; every follow-up was served from the resident cache)")


if __name__ == "__main__":
    main()
